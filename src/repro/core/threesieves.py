"""ThreeSieves (the paper's contribution) as a jittable JAX state machine.

Two execution paths with *identical* semantics (tested bit-equal):

  * ``run``          — faithful per-item ``lax.scan`` (Algorithm 1 verbatim),
  * ``run_batched``  — TPU fast path: one fused gain matmul per state change
                       plus closed-form rejection arithmetic (DESIGN.md §4).

The batched path exploits the paper's own premise — acceptances are rare —
so the expected number of fused oracle passes per batch is
1 + (#accepts in the batch).

ThreeSieves keeps a single summary plus a rejection counter, so it
specializes the shared sieve-family engine (``sieve_family.SieveAlgorithm``)
rather than the stacked one: rung descent under rejection is closed-form
((t + r) // T rungs for r rejections), not a per-instance axis.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .functions import LogDetState
from .sieve_family import SieveAlgorithm, residual_threshold

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TSState:
    ld: LogDetState
    j: Array  # () int32 — current rung of the threshold ladder
    t: Array  # () int32 — consecutive rejections at the current rung
    n_fused: Array  # () int32 — fused batch oracle passes (metrics)


@dataclasses.dataclass(frozen=True)
class ThreeSieves(SieveAlgorithm):
    """ThreeSieves(K, T, eps) over the LogDet objective.

    ``T`` is the Rule-of-Three observation count: after T consecutive
    rejections the current threshold is discarded with confidence
    p <= -ln(alpha)/T.  Keyword-only: inheriting the family base reordered
    the fields after ``f``, so positional (T, eps) calls must not compile.
    """

    eps: float = dataclasses.field(default=1e-3, kw_only=True)
    T: int = dataclasses.field(default=500, kw_only=True)

    @staticmethod
    def T_from_alpha_tau(alpha: float, tau: float) -> int:
        """Eq. (3): T = -ln(alpha)/tau  (the Rule-of-Three inverted)."""
        import math

        return int(math.ceil(-math.log(alpha) / tau))

    # ------------------------------------------------------------------ state
    def init(self) -> TSState:
        z = jnp.zeros((), jnp.int32)
        return TSState(ld=self.f.init(), j=z, t=z, n_fused=z)

    def _threshold(self, ld: LogDetState, j: Array) -> Array:
        v = self.ladder.value(j)
        return residual_threshold(v / 2.0, ld.fval, ld.n, self.f.K)

    # ------------------------------------------------------------- Algorithm 1
    def step(self, state: TSState, x: Array) -> TSState:
        """Process one stream item (lines 4-12 of Algorithm 1)."""
        f = self.f
        ld = state.ld
        gain = f.gain1(ld, x)
        thr = self._threshold(ld, state.j)
        accept = (gain >= thr) & (ld.n < f.K)

        ld2 = f.maybe_append(ld, x, accept)
        # reject branch: t += 1; if t >= T: lower rung, t = 0
        t_rej = state.t + 1
        lower = t_rej >= self.T
        j_rej = jnp.where(lower, jnp.minimum(state.j + 1, self.ladder.num_rungs - 1),
                          state.j)
        t_rej = jnp.where(lower, 0, t_rej)

        j = jnp.where(accept, state.j, j_rej)
        t = jnp.where(accept, 0, t_rej)
        ld2 = dataclasses.replace(ld2, n_queries=ld.n_queries + 1)
        return TSState(ld=ld2, j=j, t=t, n_fused=state.n_fused)

    # ---------------------------------------------------------- TPU fast path
    def run_batched(self, state: TSState, X: Array,
                    n_valid: Array | None = None) -> TSState:
        """Semantically identical to ``run`` — one fused gain pass per accept.

        Rejections are consumed in closed form:  processing r consecutive
        rejections starting from counter t advances the rung by
        (t + r) // T and leaves the counter at (t + r) % T.  Thresholds seen
        by item p (given no earlier accept) are therefore computable for the
        whole batch at once from a single gains vector.

        ``n_valid`` restricts processing to the prefix ``X[:n_valid]``
        (the session engine's ragged-chunk contract, see
        ``SieveAlgorithm.run``): the padded tail never accepts, never
        counts as a rejection, and never advances the rung.
        """
        f, T, B = self.f, self.T, X.shape[0]
        nr = self.ladder.num_rungs
        r_idx = jnp.arange(B, dtype=jnp.int32)
        nv = (jnp.int32(B) if n_valid is None
              else jnp.clip(jnp.asarray(n_valid, jnp.int32), 0, B))

        def consume_all(j, t, steps):
            lowered = (t + steps) // T
            return (jnp.minimum(j + lowered, nr - 1), (t + steps) % T)

        def cond(carry):
            _, _, _, cursor, _, _, _ = carry
            return cursor < nv

        def body(carry):
            ld, j, t, cursor, gains, valid, n_fused = carry

            def recompute():
                return f.gains(ld, X), n_fused + 1

            gains, n_fused = jax.lax.cond(
                valid, lambda: (gains, n_fused), recompute)

            # -- full summary: everything left is a rejection --------------
            def when_full():
                j2, t2 = consume_all(j, t, nv - cursor)
                return ld, j2, t2, nv, gains, True, n_fused

            # -- live summary: find the first acceptor ----------------------
            def when_live():
                r = r_idx - cursor  # position within the remaining suffix
                j_p = jnp.minimum(j + (t + r) // T, nr - 1)
                v_p = self.ladder.value(j_p)
                thr_p = residual_threshold(v_p / 2.0, ld.fval, ld.n, f.K)
                acc = (gains >= thr_p) & (r_idx >= cursor) & (r_idx < nv)
                exists = jnp.any(acc)
                istar = jnp.argmax(acc)  # first True

                def on_accept():
                    rstar = istar - cursor
                    j2 = jnp.minimum(j + (t + rstar) // T, nr - 1)
                    ld2 = f.append(ld, X[istar])
                    return (ld2, j2, jnp.int32(0), istar + 1,
                            gains, False, n_fused)

                def on_no_accept():
                    j2, t2 = consume_all(j, t, nv - cursor)
                    return ld, j2, t2, nv, gains, True, n_fused

                return jax.lax.cond(exists, on_accept, on_no_accept)

            return jax.lax.cond(ld.n >= f.K, when_full, when_live)

        # the gains carry must match the oracle's output dtype — a f32
        # literal here crashed the while-loop for LogDet(dtype=bf16)
        gains0 = jnp.zeros((B,), f.dtype)
        ld, j, t, _, _, _, n_fused = jax.lax.while_loop(
            cond, body,
            (state.ld, state.j, state.t, jnp.int32(0), gains0, False,
             state.n_fused),
        )
        ld = dataclasses.replace(ld, n_queries=ld.n_queries + nv)
        return TSState(ld=ld, j=j, t=t, n_fused=n_fused)

    # ---------------------------------------------------------------- metrics
    def summary(self, state: TSState) -> Tuple[Array, Array, Array]:
        return state.ld.feats, state.ld.n, state.ld.fval

    def insertions(self, state: TSState) -> Array:
        return state.ld.n  # single append-only summary

    def memory_elements(self, state: TSState) -> int:
        return self.f.K  # a single summary — the paper's O(K)
