"""ThreeSieves (the paper's contribution) as a jittable JAX state machine.

Two execution paths with *identical* semantics (tested bit-equal):

  * ``run``          — faithful per-item ``lax.scan`` (Algorithm 1 verbatim),
  * ``run_batched``  — TPU fast path: one fused gain matmul per state change
                       plus closed-form rejection arithmetic (DESIGN.md §4).

The batched path exploits the paper's own premise — acceptances are rare —
so the expected number of fused oracle passes per batch is
1 + (#accepts in the batch).

ThreeSieves keeps a single summary plus a rejection counter, so it
specializes the shared sieve-family engine (``sieve_family.SieveAlgorithm``)
rather than the stacked one: rung descent under rejection is closed-form
((t + r) // T rungs for r rejections), not a per-instance axis.

(K, T, eps) are *state*, not trace constants: ``TSState.hp`` carries them
as () arrays (``spec.HyperParams``), so one compiled program hosts any
budget up to the ``f.K`` buffer capacity — ``init(algo.hyper(K=..., T=...,
eps=...))`` selects it per run, and a SummarizerPod stamps one row per
tenant (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .functions import LogDetState
from .sieve_family import SieveAlgorithm, residual_threshold
from .spec import HyperParams
from .thresholds import TracedLadder

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TSState:
    ld: LogDetState
    j: Array  # () int32 — current rung of the threshold ladder
    t: Array  # () int32 — consecutive rejections at the current rung
    n_fused: Array  # () int32 — fused batch oracle passes (metrics)
    hp: HyperParams  # traced (K, T, eps) + ladder bounds, all () leaves


@dataclasses.dataclass(frozen=True)
class ThreeSieves(SieveAlgorithm):
    """ThreeSieves(K, T, eps) over the LogDet objective.

    ``T`` is the Rule-of-Three observation count: after T consecutive
    rejections the current threshold is discarded with confidence
    p <= -ln(alpha)/T.  Keyword-only: inheriting the family base reordered
    the fields after ``f``, so positional (T, eps) calls must not compile.
    ``T``/``eps`` here are the *defaults* stamped into ``init()``'s
    hyperparams; the run itself reads ``state.hp``.
    """

    eps: float = dataclasses.field(default=1e-3, kw_only=True)
    T: int = dataclasses.field(default=500, kw_only=True)

    @staticmethod
    def T_from_alpha_tau(alpha: float, tau: float) -> int:
        """Eq. (3): T = -ln(alpha)/tau  (the Rule-of-Three inverted)."""
        import math

        return int(math.ceil(-math.log(alpha) / tau))

    # ------------------------------------------------------------------ state
    def init(self, hyper: HyperParams | None = None) -> TSState:
        z = jnp.zeros((), jnp.int32)
        hp = self.default_hyper() if hyper is None else hyper
        return TSState(ld=self.f.init(), j=z, t=z, n_fused=z, hp=hp)

    def _threshold(self, ld: LogDetState, j: Array, hp: HyperParams) -> Array:
        v = TracedLadder.of(hp).value(j, self.f.dtype)
        return residual_threshold(v / 2.0, ld.fval, ld.n, hp.k_cap)

    # ------------------------------------------------------------- Algorithm 1
    def step(self, state: TSState, x: Array) -> TSState:
        """Process one stream item (lines 4-12 of Algorithm 1)."""
        f, hp = self.f, state.hp
        ld = state.ld
        gain = f.gain1(ld, x, hp.kern)
        thr = self._threshold(ld, state.j, hp)
        accept = (gain >= thr) & (ld.n < hp.k_cap)

        ld2 = f.maybe_append(ld, x, accept, hp.kern)
        # reject branch: t += 1; if t >= T: lower rung, t = 0
        t_rej = state.t + 1
        lower = t_rej >= hp.T
        j_rej = jnp.where(lower, jnp.minimum(state.j + 1, hp.num_rungs - 1),
                          state.j)
        t_rej = jnp.where(lower, 0, t_rej)

        j = jnp.where(accept, state.j, j_rej)
        t = jnp.where(accept, 0, t_rej)
        ld2 = dataclasses.replace(ld2, n_queries=ld.n_queries + 1)
        return TSState(ld=ld2, j=j, t=t, n_fused=state.n_fused, hp=hp)

    # ---------------------------------------------------------- TPU fast path
    def run_batched(self, state: TSState, X: Array,
                    n_valid: Array | None = None) -> TSState:
        """Semantically identical to ``run`` — one fused gain pass per accept.

        Rejections are consumed in closed form:  processing r consecutive
        rejections starting from counter t advances the rung by
        (t + r) // T and leaves the counter at (t + r) % T.  Thresholds seen
        by item p (given no earlier accept) are therefore computable for the
        whole batch at once from a single gains vector.

        ``n_valid`` restricts processing to the prefix ``X[:n_valid]``
        (the session engine's ragged-chunk contract, see
        ``SieveAlgorithm.run``): the padded tail never accepts, never
        counts as a rejection, and never advances the rung.

        T, K and the ladder all come from ``state.hp`` — under the pod's
        ``vmap`` each session runs its own (traced) hyperparams through
        this one program.
        """
        f, B = self.f, X.shape[0]
        hp = state.hp
        T, nr, k_cap = hp.T, hp.num_rungs, hp.k_cap
        lad = TracedLadder.of(hp)
        r_idx = jnp.arange(B, dtype=jnp.int32)
        nv = (jnp.int32(B) if n_valid is None
              else jnp.clip(jnp.asarray(n_valid, jnp.int32), 0, B))

        def consume_all(j, t, steps):
            lowered = (t + steps) // T
            return (jnp.minimum(j + lowered, nr - 1), (t + steps) % T)

        def cond(carry):
            _, _, _, cursor, _, _, _ = carry
            return cursor < nv

        def body(carry):
            ld, j, t, cursor, gains, valid, n_fused = carry

            def recompute():
                return f.gains(ld, X, hp.kern), n_fused + 1

            gains, n_fused = jax.lax.cond(
                valid, lambda: (gains, n_fused), recompute)

            # -- full summary: everything left is a rejection --------------
            def when_full():
                j2, t2 = consume_all(j, t, nv - cursor)
                return ld, j2, t2, nv, gains, True, n_fused

            # -- live summary: find the first acceptor ----------------------
            def when_live():
                r = r_idx - cursor  # position within the remaining suffix
                j_p = jnp.minimum(j + (t + r) // T, nr - 1)
                v_p = lad.value(j_p, f.dtype)
                thr_p = residual_threshold(v_p / 2.0, ld.fval, ld.n, k_cap)
                acc = (gains >= thr_p) & (r_idx >= cursor) & (r_idx < nv)
                exists = jnp.any(acc)
                istar = jnp.argmax(acc)  # first True

                def on_accept():
                    rstar = istar - cursor
                    j2 = jnp.minimum(j + (t + rstar) // T, nr - 1)
                    ld2 = f.append(ld, X[istar], hp.kern)
                    return (ld2, j2, jnp.int32(0), istar + 1,
                            gains, False, n_fused)

                def on_no_accept():
                    j2, t2 = consume_all(j, t, nv - cursor)
                    return ld, j2, t2, nv, gains, True, n_fused

                return jax.lax.cond(exists, on_accept, on_no_accept)

            return jax.lax.cond(ld.n >= k_cap, when_full, when_live)

        # the gains carry must match the oracle's output dtype — a f32
        # literal here crashed the while-loop for LogDet(dtype=bf16)
        gains0 = jnp.zeros((B,), f.dtype)
        ld, j, t, _, _, _, n_fused = jax.lax.while_loop(
            cond, body,
            (state.ld, state.j, state.t, jnp.int32(0), gains0, False,
             state.n_fused),
        )
        ld = dataclasses.replace(ld, n_queries=ld.n_queries + nv)
        return TSState(ld=ld, j=j, t=t, n_fused=n_fused, hp=hp)

    # ---------------------------------------------------------------- metrics
    def summary(self, state: TSState) -> Tuple[Array, Array, Array]:
        return state.ld.feats, state.ld.n, state.ld.fval

    def insertions(self, state: TSState) -> Array:
        return state.ld.n  # single append-only summary

    def memory_elements(self, state: TSState) -> Array:
        return state.hp.k_cap  # a single summary — the paper's O(K)
