"""Streaming baselines the paper compares against (Table 1):

  * Random                     — reservoir sampling (Feige et al. 2011: 1/4 exp.)
  * IndependentSetImprovement  — Chakrabarti & Kale 2014 (1/4)
  * PreemptionStreaming        — Buchbinder et al. 2019 (1/4) [survey-only in
                                 the paper; included for completeness]
  * QuickStream                — Kuhnle 2021 [survey-only; included]

Replacement-based algorithms invalidate the incremental Cholesky factors, so
replacements trigger a full O(K^3) refactor (`LogDet.refactor`) — faithful to
the reference implementations, which re-evaluate f from scratch as well.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .functions import LogDet, LogDetState

Array = jax.Array


# ---------------------------------------------------------------------------
# Random (reservoir sampling)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RandomState:
    feats: Array  # (K, d)
    n: Array  # () int32 live rows
    seen: Array  # () int32 items observed
    key: Array


@dataclasses.dataclass(frozen=True)
class RandomReservoir:
    f: LogDet

    def init(self, seed: int = 0) -> RandomState:
        return RandomState(
            feats=jnp.zeros((self.f.K, self.f.d), self.f.dtype),
            n=jnp.zeros((), jnp.int32),
            seen=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(seed),
        )

    def step(self, state: RandomState, x: Array) -> RandomState:
        K = self.f.K
        key, sub = jax.random.split(state.key)
        j = jax.random.randint(sub, (), 0, state.seen + 1)
        fill = state.n < K
        slot = jnp.where(fill, state.n, j)
        take = fill | (j < K)
        feats = jnp.where(take, state.feats.at[slot].set(x), state.feats)
        return RandomState(
            feats=feats,
            n=jnp.minimum(state.n + fill.astype(jnp.int32), K),
            seen=state.seen + 1,
            key=key,
        )

    def run(self, state: RandomState, X: Array) -> RandomState:
        def body(s, x):
            return self.step(s, x), None

        out, _ = jax.lax.scan(body, state, X)
        return out

    def run_batched(self, state: RandomState, X: Array) -> RandomState:
        """Uniform-protocol alias — no batched fast path for this baseline."""
        return self.run(state, X)

    def summary(self, state: RandomState) -> Tuple[Array, Array, Array]:
        fval = self.f.evaluate(state.feats, state.n)
        return state.feats, state.n, fval

    def memory_elements(self, state) -> int:
        return self.f.K


# ---------------------------------------------------------------------------
# IndependentSetImprovement
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ISIState:
    ld: LogDetState
    w: Array  # (K,) insertion-time marginal gains ("weights", never updated)


@dataclasses.dataclass(frozen=True)
class IndependentSetImprovement:
    f: LogDet

    def init(self) -> ISIState:
        # w follows f.dtype (inf is representable in bf16): an implicit
        # float32 here upcast every bf16 gain at insertion, so the
        # replacement comparisons ran in a dtype the objective never
        # produced (and float64 under x64)
        return ISIState(ld=self.f.init(),
                        w=jnp.full((self.f.K,), jnp.inf, self.f.dtype))

    def step(self, state: ISIState, x: Array) -> ISIState:
        f = self.f
        ld = state.ld
        g = f.gain1(ld, x)

        def fill(_):
            slot = ld.n
            ld2 = f.append(ld, x)
            return ISIState(ld=ld2, w=state.w.at[slot].set(g))

        def maybe_replace(_):
            am = jnp.argmin(state.w)
            wmin = state.w[am]

            def replace(_):
                feats = ld.feats.at[am].set(x.astype(f.dtype))
                ld2 = f.refactor(feats, ld.n)
                ld2 = dataclasses.replace(ld2, n_queries=ld.n_queries)
                return ISIState(ld=ld2, w=state.w.at[am].set(g))

            return jax.lax.cond(g > 2.0 * wmin, replace,
                                lambda _: state, None)

        out = jax.lax.cond(ld.n < f.K, fill, maybe_replace, None)
        out = ISIState(
            ld=dataclasses.replace(out.ld, n_queries=ld.n_queries + 1), w=out.w
        )
        return out

    def run(self, state: ISIState, X: Array) -> ISIState:
        def body(s, x):
            return self.step(s, x), None

        out, _ = jax.lax.scan(body, state, X)
        return out

    def run_batched(self, state: ISIState, X: Array) -> ISIState:
        """Uniform-protocol alias — no batched fast path for this baseline."""
        return self.run(state, X)

    def summary(self, state: ISIState) -> Tuple[Array, Array, Array]:
        return state.ld.feats, state.ld.n, state.ld.fval

    def memory_elements(self, state) -> int:
        return self.f.K


# ---------------------------------------------------------------------------
# PreemptionStreaming (swap if it improves f by >= f(S)/K)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PreemptionStreaming:
    f: LogDet

    def init(self) -> LogDetState:
        return self.f.init()

    def step(self, ld: LogDetState, x: Array) -> LogDetState:
        f = self.f

        def fill(_):
            return f.append(ld, x)

        def preempt(_):
            def swapped_val(v):
                feats = ld.feats.at[v].set(x.astype(f.dtype))
                return f.evaluate(feats, ld.n)

            vals = jax.vmap(swapped_val)(jnp.arange(f.K))
            u = jnp.argmax(vals)

            def replace(_):
                feats = ld.feats.at[u].set(x.astype(f.dtype))
                ld2 = f.refactor(feats, ld.n)
                return dataclasses.replace(ld2, n_queries=ld.n_queries)

            return jax.lax.cond(
                vals[u] - ld.fval >= ld.fval / f.K, replace, lambda _: ld, None
            )

        out = jax.lax.cond(ld.n < f.K, fill, preempt, None)
        return dataclasses.replace(out, n_queries=ld.n_queries + f.K)

    def run(self, ld: LogDetState, X: Array) -> LogDetState:
        def body(s, x):
            return self.step(s, x), None

        out, _ = jax.lax.scan(body, ld, X)
        return out

    def run_batched(self, ld: LogDetState, X: Array) -> LogDetState:
        """Uniform-protocol alias — no batched fast path for this baseline."""
        return self.run(ld, X)

    def summary(self, ld: LogDetState) -> Tuple[Array, Array, Array]:
        return ld.feats, ld.n, ld.fval

    def memory_elements(self, state) -> int:
        return self.f.K


# ---------------------------------------------------------------------------
# QuickStream (buffered bulk-accept; fixed-shape ring buffer)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QSState:
    buf: Array  # (c, d) pending chunk
    nbuf: Array  # () int32
    A: Array  # (cap, d) accepted ring
    nA: Array  # () int32 (total ever accepted; ring position = nA % cap)
    fA: Array  # () float32  f(A) of the live window
    n_queries: Array


@dataclasses.dataclass(frozen=True)
class QuickStream:
    """Kuhnle 2021, with the unbounded buffer replaced by a ring of size
    ``cap = c * K`` (the final trim size) — a fixed-memory simplification
    noted in EXPERIMENTS.md.
    """

    f: LogDet
    c: int = 4

    @property
    def cap(self) -> int:
        return self.c * self.f.K

    def init(self) -> QSState:
        z = jnp.zeros((), jnp.int32)
        return QSState(
            buf=jnp.zeros((self.c, self.f.d), self.f.dtype),
            nbuf=z,
            A=jnp.zeros((self.cap, self.f.d), self.f.dtype),
            nA=z,
            fA=jnp.zeros((), jnp.float32),
            n_queries=z,
        )

    def _window(self, state: QSState) -> Tuple[Array, Array]:
        n_live = jnp.minimum(state.nA, self.cap)
        return state.A, n_live

    def step(self, state: QSState, x: Array) -> QSState:
        buf = state.buf.at[state.nbuf].set(x.astype(self.f.dtype))
        nbuf = state.nbuf + 1

        def flush(_):
            A, n_live = self._window(state)
            # candidate: append the c buffered items into the ring
            idx = (state.nA + jnp.arange(self.c)) % self.cap
            A2 = A.at[idx].set(buf)
            n2 = jnp.minimum(state.nA + self.c, self.cap)
            f2 = self.f.evaluate(A2, n2)

            def take(_):
                return QSState(buf=jnp.zeros_like(buf), nbuf=jnp.int32(0),
                               A=A2, nA=state.nA + self.c, fA=f2,
                               n_queries=state.n_queries + 1)

            def drop(_):
                return QSState(buf=jnp.zeros_like(buf), nbuf=jnp.int32(0),
                               A=state.A, nA=state.nA, fA=state.fA,
                               n_queries=state.n_queries + 1)

            return jax.lax.cond(
                f2 - state.fA >= state.fA / self.f.K, take, drop, None
            )

        def hold(_):
            return QSState(buf=buf, nbuf=nbuf, A=state.A, nA=state.nA,
                           fA=state.fA, n_queries=state.n_queries)

        return jax.lax.cond(nbuf >= self.c, flush, hold, None)

    def run(self, state: QSState, X: Array,
            n_valid: Array | None = None) -> QSState:
        """Per-item scan; ``n_valid`` (dynamic, optional) restricts
        processing to the prefix ``X[:n_valid]`` with the padded tail
        leaving the state bit-untouched — the sieve family's
        ragged-chunk contract (``sieve_family.SieveAlgorithm.run``),
        extended to this ring-buffer baseline so it can tenant a
        mixed-algorithm SummarizerPod."""
        if n_valid is None:
            def body(s, x):
                return self.step(s, x), None

            out, _ = jax.lax.scan(body, state, X)
            return out

        idx = jnp.arange(X.shape[0], dtype=jnp.int32)

        def body(s, xi):
            x, i = xi
            s2 = self.step(s, x)
            keep = i < n_valid
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(keep, a, b), s2, s), None

        out, _ = jax.lax.scan(body, state, (X, idx))
        return out

    def run_batched(self, state: QSState, X: Array,
                    n_valid: Array | None = None) -> QSState:
        """Uniform-protocol alias — no batched fast path for this baseline."""
        return self.run(state, X, n_valid)

    def insertions(self, state: QSState) -> Array:
        """Total ring insertions ever — () int32, monotone (``nA`` never
        decreases; the live window is ``min(nA, cap)``).  The session
        engine's accept-activity metric."""
        return state.nA

    def summary(self, state: QSState) -> Tuple[Array, Array, Array]:
        """Final step: greedy-ish pick of K from the ring (best partition)."""
        A, n_live = self._window(state)
        # deterministic partition into c groups of K (random partition in the
        # paper); evaluate each and return the best.
        def group_val(g):
            feats = jax.lax.dynamic_slice_in_dim(A, g * self.f.K, self.f.K, 0)
            n = jnp.clip(n_live - g * self.f.K, 0, self.f.K)
            return self.f.evaluate(feats, n)

        vals = jax.vmap(group_val)(jnp.arange(self.c))
        g = jnp.argmax(vals)
        feats = jax.lax.dynamic_slice_in_dim(A, g * self.f.K, self.f.K, 0)
        n = jnp.clip(n_live - g * self.f.K, 0, self.f.K)
        return feats, n, vals[g]

    def memory_elements(self, state) -> int:
        return self.cap + self.c
