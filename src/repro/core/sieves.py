"""SieveStreaming (Badanidiyuru et al. 2014) and SieveStreaming++
(Kazemi et al. 2019) — the paper's strongest streaming baselines.

Both manage one summary per rung of the threshold ladder; we store them as a
*stacked* pytree of LogDetStates and vmap the per-sieve update.  On SIMD
hardware every live sieve is updated in lockstep — the resource cost the
paper's ThreeSieves removes is plainly visible as the leading (num_rungs,)
axis of every buffer.

SieveStreaming++ additionally tracks LB = max_v f(S_v) and deactivates rungs
below tau_min = max(LB, m) / (2K).  Fixed-shape JAX buffers cannot shrink, so
the paper-comparable *effective memory* (live sieves) is reported from the
activity mask by ``memory_elements``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .functions import LogDet, LogDetState
from .thresholds import Ladder

Array = jax.Array


def _stack(tree, n: int):
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), tree
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SieveState:
    lds: LogDetState  # stacked over rungs: leading axis (num_rungs,)
    alive: Array  # (num_rungs,) bool — SS++ deactivation (all True for SS)
    lb: Array  # () float32 — best f seen (SS++ only)
    n_queries: Array  # () int32
    peak_mem: Array  # () int32 — max live stored elements (paper metric)


@dataclasses.dataclass(frozen=True)
class SieveStreaming:
    """Classic SieveStreaming: every rung is always live."""

    f: LogDet
    eps: float = 0.1
    plus_plus: bool = False  # SieveStreaming++ behaviour

    @property
    def ladder(self) -> Ladder:
        return Ladder(eps=self.eps, m=self.f.singleton_value, K=self.f.K)

    def init(self) -> SieveState:
        nv = self.ladder.num_rungs
        return SieveState(
            lds=_stack(self.f.init(), nv),
            alive=jnp.ones((nv,), bool),
            lb=jnp.zeros((), jnp.float32),
            n_queries=jnp.zeros((), jnp.int32),
            peak_mem=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------------------------ step
    def step(self, state: SieveState, x: Array) -> SieveState:
        f = self.f
        vs = self.ladder.values()  # (nv,)

        def one(ld: LogDetState, v: Array, active: Array) -> LogDetState:
            gain = f.gain1(ld, x)
            denom = jnp.maximum(f.K - ld.n, 1).astype(ld.fval.dtype)
            thr = (v / 2.0 - ld.fval) / denom
            take = (gain >= thr) & (ld.n < f.K) & active
            return f.maybe_append(ld, x, take)

        lds = jax.vmap(one, in_axes=(0, 0, 0))(state.lds, vs, state.alive)

        lb = jnp.maximum(state.lb, jnp.max(lds.fval)) if self.plus_plus else state.lb
        if self.plus_plus:
            # v is an OPT guess: once LB = max_v f(S_v) exceeds v, the guess
            # cannot lie in [(1-eps) OPT, OPT] any more -> kill the sieve.
            # (Kazemi et al. state this via tau_min = max(LB, m)/(2K) on the
            # per-item thresholds; v < LB is the same test on OPT guesses.)
            alive = state.alive & (vs > lb)
        else:
            alive = state.alive
        nq = state.n_queries + jnp.sum(alive.astype(jnp.int32))
        peak = jnp.maximum(state.peak_mem,
                           jnp.sum(jnp.where(alive, lds.n, 0)))
        return SieveState(lds=lds, alive=alive, lb=lb, n_queries=nq,
                          peak_mem=peak)

    def run(self, state: SieveState, X: Array) -> SieveState:
        def body(s, x):
            return self.step(s, x), None

        out, _ = jax.lax.scan(body, state, X)
        return out

    # --------------------------------------------------------------- results
    def best(self, state: SieveState) -> Tuple[Array, Array, Array]:
        """(feats, n, fval) of the winning sieve."""
        i = jnp.argmax(jnp.where(state.alive, state.lds.fval, -jnp.inf))
        pick = lambda l: l[i]
        return (pick(state.lds.feats), pick(state.lds.n), pick(state.lds.fval))

    def summary(self, state: SieveState):
        return self.best(state)

    def memory_elements(self, state: SieveState) -> Array:
        """Paper-comparable metric: PEAK live stored elements (the paper's
        figures plot maximum memory; SS++ deactivation can end a run with
        only empty high-threshold sieves alive)."""
        return state.peak_mem


def sieve_streaming_pp(f: LogDet, eps: float = 0.1) -> SieveStreaming:
    return SieveStreaming(f=f, eps=eps, plus_plus=True)
