"""SieveStreaming (Badanidiyuru et al. 2014) and SieveStreaming++
(Kazemi et al. 2019) — the paper's strongest streaming baselines.

Both manage one summary per rung of the threshold ladder; we store them as a
*stacked* pytree of LogDetStates and vmap the per-sieve update.  On SIMD
hardware every live sieve is updated in lockstep — the resource cost the
paper's ThreeSieves removes is plainly visible as the leading (rung_cap,)
axis of every buffer.

SieveStreaming++ additionally tracks LB = max_v f(S_v) and deactivates rungs
below tau_min = max(LB, m) / (2K).  Fixed-shape JAX buffers cannot shrink, so
the paper-comparable *effective memory* (live sieves) is reported from the
activity mask by ``memory_elements``.

Both execution paths — per-item ``run`` and the chunked ``run_batched``
fast path (one fused gains pass per state change) — derive from the shared
``StackedSieve`` engine in ``sieve_family`` (DESIGN.md §4).

(K, eps) are traced state (``SieveState.hp``): the instance axis is sized
by the construction-time defaults (the rung *capacity*), and a session
with a smaller ladder occupies a prefix of it — the tail instances start
dead (``TracedLadder.valid``) and never accept, so heterogeneous budgets
share one compiled program (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .functions import LogDet, LogDetState
from .sieve_family import StackedSieve, residual_threshold, stack_states
from .spec import HyperParams
from .thresholds import TracedLadder

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SieveState:
    lds: LogDetState  # stacked over rungs: leading axis (rung_cap,)
    alive: Array  # (rung_cap,) bool — ladder-validity mask, further
    # deactivated by SS++ (all live rungs stay True for plain SS)
    lb: Array  # () f.dtype — best f seen (SS++ only)
    n_queries: Array  # () int32
    peak_mem: Array  # () int32 — max live stored elements (paper metric)
    hp: HyperParams  # traced (K, T, eps) + ladder bounds, all () leaves


@dataclasses.dataclass(frozen=True)
class SieveStreaming(StackedSieve):
    """Classic SieveStreaming: every (valid) rung is always live."""

    plus_plus: bool = False  # SieveStreaming++ behaviour

    @property
    def n_instances(self) -> int:
        return self.rung_cap

    def init(self, hyper: HyperParams | None = None) -> SieveState:
        nv = self.rung_cap
        hp = self.default_hyper() if hyper is None else hyper
        return SieveState(
            lds=stack_states(self.f.init(), nv),
            alive=TracedLadder.of(hp).valid(nv),
            lb=jnp.zeros((), self.f.dtype),
            n_queries=jnp.zeros((), jnp.int32),
            peak_mem=jnp.zeros((), jnp.int32),
            hp=hp,
        )

    # ------------------------------------------------- per-item decision parts
    def _values(self, state: SieveState) -> Array:
        """(rung_cap,) OPT guesses in the objective's dtype."""
        return TracedLadder.of(state.hp).values(self.rung_cap, self.f.dtype)

    def _thresholds(self, state: SieveState) -> Array:
        vs = self._values(state)  # (nv,)
        return residual_threshold(vs / 2.0, state.lds.fval, state.lds.n,
                                  state.hp.k_cap)

    def _can_accept(self, state: SieveState) -> Array:
        return state.alive & (state.lds.n < state.hp.k_cap)

    def _apply_item(self, state: SieveState, x: Array,
                    takes: Array) -> SieveState:
        f, kern = self.f, state.hp.kern
        lds = jax.vmap(lambda ld, take: f.maybe_append(ld, x, take, kern))(
            state.lds, takes)

        if self.plus_plus:
            lb = jnp.maximum(state.lb, jnp.max(lds.fval))
            # v is an OPT guess: once LB = max_v f(S_v) exceeds v, the guess
            # cannot lie in [(1-eps) OPT, OPT] any more -> kill the sieve.
            # (Kazemi et al. state this via tau_min = max(LB, m)/(2K) on the
            # per-item thresholds; v < LB is the same test on OPT guesses.)
            alive = state.alive & (self._values(state) > lb)
        else:
            lb, alive = state.lb, state.alive
        nq = state.n_queries + jnp.sum(alive.astype(jnp.int32))
        peak = jnp.maximum(state.peak_mem,
                           jnp.sum(jnp.where(alive, lds.n, 0)))
        return SieveState(lds=lds, alive=alive, lb=lb, n_queries=nq,
                          peak_mem=peak, hp=state.hp)

    def _bulk_reject(self, state: SieveState, r: Array) -> SieveState:
        """r consecutive all-reject items in closed form.

        Rejections leave every summary — hence lb, alive and the live
        element count — unchanged, so only the query counter moves.
        """
        nq = state.n_queries + r * jnp.sum(state.alive.astype(jnp.int32))
        peak = jnp.maximum(state.peak_mem,
                           jnp.sum(jnp.where(state.alive, state.lds.n, 0)))
        return dataclasses.replace(state, n_queries=nq, peak_mem=peak)

    # --------------------------------------------------------------- results
    def best(self, state: SieveState) -> Tuple[Array, Array, Array]:
        """(feats, n, fval) of the winning sieve."""
        i = jnp.argmax(jnp.where(state.alive, state.lds.fval, -jnp.inf))
        pick = lambda l: l[i]
        return (pick(state.lds.feats), pick(state.lds.n), pick(state.lds.fval))

    def summary(self, state: SieveState):
        return self.best(state)

    def memory_elements(self, state: SieveState) -> Array:
        """Paper-comparable metric: PEAK live stored elements (the paper's
        figures plot maximum memory; SS++ deactivation can end a run with
        only empty high-threshold sieves alive)."""
        return state.peak_mem


def sieve_streaming_pp(f: LogDet, eps: float = 0.1) -> SieveStreaming:
    return SieveStreaming(f=f, eps=eps, plus_plus=True)
