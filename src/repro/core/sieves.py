"""SieveStreaming (Badanidiyuru et al. 2014) and SieveStreaming++
(Kazemi et al. 2019) — the paper's strongest streaming baselines.

Both manage one summary per rung of the threshold ladder; we store them as a
*stacked* pytree of LogDetStates and vmap the per-sieve update.  On SIMD
hardware every live sieve is updated in lockstep — the resource cost the
paper's ThreeSieves removes is plainly visible as the leading (num_rungs,)
axis of every buffer.

SieveStreaming++ additionally tracks LB = max_v f(S_v) and deactivates rungs
below tau_min = max(LB, m) / (2K).  Fixed-shape JAX buffers cannot shrink, so
the paper-comparable *effective memory* (live sieves) is reported from the
activity mask by ``memory_elements``.

Both execution paths — per-item ``run`` and the chunked ``run_batched``
fast path (one fused gains pass per state change) — derive from the shared
``StackedSieve`` engine in ``sieve_family`` (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .functions import LogDet, LogDetState
from .sieve_family import StackedSieve, residual_threshold, stack_states

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SieveState:
    lds: LogDetState  # stacked over rungs: leading axis (num_rungs,)
    alive: Array  # (num_rungs,) bool — SS++ deactivation (all True for SS)
    lb: Array  # () float32 — best f seen (SS++ only)
    n_queries: Array  # () int32
    peak_mem: Array  # () int32 — max live stored elements (paper metric)


@dataclasses.dataclass(frozen=True)
class SieveStreaming(StackedSieve):
    """Classic SieveStreaming: every rung is always live."""

    plus_plus: bool = False  # SieveStreaming++ behaviour

    @property
    def n_instances(self) -> int:
        return self.ladder.num_rungs

    def init(self) -> SieveState:
        nv = self.ladder.num_rungs
        return SieveState(
            lds=stack_states(self.f.init(), nv),
            alive=jnp.ones((nv,), bool),
            lb=jnp.zeros((), jnp.float32),
            n_queries=jnp.zeros((), jnp.int32),
            peak_mem=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------- per-item decision parts
    def _thresholds(self, state: SieveState) -> Array:
        vs = self.ladder.values()  # (nv,)
        return residual_threshold(vs / 2.0, state.lds.fval, state.lds.n,
                                  self.f.K)

    def _can_accept(self, state: SieveState) -> Array:
        return state.alive & (state.lds.n < self.f.K)

    def _apply_item(self, state: SieveState, x: Array,
                    takes: Array) -> SieveState:
        f = self.f
        lds = jax.vmap(lambda ld, take: f.maybe_append(ld, x, take))(
            state.lds, takes)

        if self.plus_plus:
            lb = jnp.maximum(state.lb, jnp.max(lds.fval))
            # v is an OPT guess: once LB = max_v f(S_v) exceeds v, the guess
            # cannot lie in [(1-eps) OPT, OPT] any more -> kill the sieve.
            # (Kazemi et al. state this via tau_min = max(LB, m)/(2K) on the
            # per-item thresholds; v < LB is the same test on OPT guesses.)
            alive = state.alive & (self.ladder.values() > lb)
        else:
            lb, alive = state.lb, state.alive
        nq = state.n_queries + jnp.sum(alive.astype(jnp.int32))
        peak = jnp.maximum(state.peak_mem,
                           jnp.sum(jnp.where(alive, lds.n, 0)))
        return SieveState(lds=lds, alive=alive, lb=lb, n_queries=nq,
                          peak_mem=peak)

    def _bulk_reject(self, state: SieveState, r: Array) -> SieveState:
        """r consecutive all-reject items in closed form.

        Rejections leave every summary — hence lb, alive and the live
        element count — unchanged, so only the query counter moves.
        """
        nq = state.n_queries + r * jnp.sum(state.alive.astype(jnp.int32))
        peak = jnp.maximum(state.peak_mem,
                           jnp.sum(jnp.where(state.alive, state.lds.n, 0)))
        return dataclasses.replace(state, n_queries=nq, peak_mem=peak)

    # --------------------------------------------------------------- results
    def best(self, state: SieveState) -> Tuple[Array, Array, Array]:
        """(feats, n, fval) of the winning sieve."""
        i = jnp.argmax(jnp.where(state.alive, state.lds.fval, -jnp.inf))
        pick = lambda l: l[i]
        return (pick(state.lds.feats), pick(state.lds.n), pick(state.lds.fval))

    def summary(self, state: SieveState):
        return self.best(state)

    def memory_elements(self, state: SieveState) -> Array:
        """Paper-comparable metric: PEAK live stored elements (the paper's
        figures plot maximum memory; SS++ deactivation can end a run with
        only empty high-threshold sieves alive)."""
        return state.peak_mem


def sieve_streaming_pp(f: LogDet, eps: float = 0.1) -> SieveStreaming:
    return SieveStreaming(f=f, eps=eps, plus_plus=True)
