"""Submodular objective functions as incremental, jittable JAX state machines.

The paper's workhorse objective is the Informative Vector Machine (IVM)
log-determinant

    f(S) = 1/2 * log det(I + a * Sigma_S),   Sigma_S[i, j] = k(e_i, e_j)

which is non-negative, monotone and submodular for any PSD kernel k
(Seeger 2004).  For a *normalized* kernel (k(e, e) = 1) the maximum singleton
value is known analytically:  m = f({e}) = 1/2 * log(1 + a).

TPU-native formulation (see DESIGN.md §3)
-----------------------------------------
We maintain, incrementally and in fixed-shape (K, ...) zero-padded buffers:

  * ``feats``  (K, d)   the selected items,
  * ``L``      (K, K)   Cholesky factor of  M = I + a * Sigma_S,
  * ``Linv``   (K, K)   its explicit inverse,
  * ``n``               number of live rows,
  * ``fval``            current objective value  ( = sum(log diag L) ).

Appending an element e:

    c   = Linv @ (a * k_S(e))            # O(K^2) matmul row
    dd  = sqrt((1 + a) - ||c||^2)
    df  = log dd                         # the marginal gain
    L   <- [[L, 0], [c^T, dd]]
    Linv<- [[Linv, 0], [-(c^T Linv)/dd, 1/dd]]

Because ``Linv`` is explicit, the marginal gain of a *batch* of B candidates
is a dense (K,K)x(K,B) matmul + column norms + log — pure MXU work, no
sequential triangular solves.  This is the hardware adaptation of the paper's
"one oracle query per element".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.constants import GAIN_EPS, NORM_EPS

Array = jax.Array

# ---------------------------------------------------------------------------
# Kernel functions
# ---------------------------------------------------------------------------

# Traced-kernel math lives in the cycle-free ``repro.kernelmath`` (shared
# with the Pallas kernel bodies); re-exported here as the core API.
from repro.kernelmath import (  # noqa: E402  (re-export)
    KERNEL_KIND_IDS, KernelParams, pairwise_traced, traced_gain_rows)

__all__ = [
    "KERNEL_KIND_IDS", "KernelConfig", "KernelParams", "LogDet",
    "LogDetState", "naive_logdet", "pairwise_traced",
    "rbf_lengthscale_batch", "rbf_lengthscale_stream", "traced_gain_rows",
]


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Positive-definite kernel. ``rbf`` is the paper's choice.

    lengthscale convention follows the paper: l = 1/(2 sqrt(d)) for the batch
    experiments, l = 1/sqrt(d) for the streaming experiments.
    """

    kind: str = "rbf"  # "rbf" | "linear_norm"
    lengthscale: float = 1.0

    def pairwise(self, x: Array, y: Array) -> Array:
        """k(x_i, y_j) for x (N, d), y (M, d) -> (N, M)."""
        if self.kind == "rbf":
            # squared distances via the expanded form (MXU friendly).
            xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (N, 1)
            yn = jnp.sum(y * y, axis=-1, keepdims=True).T  # (1, M)
            d2 = jnp.maximum(xn + yn - 2.0 * (x @ y.T), 0.0)
            return jnp.exp(-d2 / (2.0 * self.lengthscale**2))
        if self.kind == "linear_norm":
            # normalized linear kernel: <x, y> / (|x||y|)  in [-1, 1] -> [0,1]
            xs = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True),
                                 NORM_EPS)
            ys = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True),
                                 NORM_EPS)
            return 0.5 * (xs @ ys.T + 1.0)
        raise ValueError(f"unknown kernel {self.kind}")


def rbf_lengthscale_batch(d: int) -> float:
    """Paper's batch-experiment lengthscale l = 1/(2 sqrt(d))."""
    return 1.0 / (2.0 * (d**0.5))


def rbf_lengthscale_stream(d: int) -> float:
    """Paper's streaming-experiment lengthscale l = 1/sqrt(d)."""
    return 1.0 / (d**0.5)


# ---------------------------------------------------------------------------
# Incremental log-det state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LogDetState:
    """Fixed-shape summary state for f(S) = 1/2 log det(I + a Sigma_S)."""

    feats: Array  # (K, d) zero padded
    L: Array  # (K, K) lower triangular, identity on padded rows
    Linv: Array  # (K, K)
    n: Array  # () int32 — number of live rows
    fval: Array  # () float32 — current f(S)
    n_queries: Array  # () int32 — oracle queries issued (metrics only)

    @property
    def K(self) -> int:
        return self.feats.shape[0]


@dataclasses.dataclass(frozen=True)
class LogDet:
    """The IVM objective bound to a kernel and scale ``a``.

    All methods are pure and jittable; ``self`` is a static argument.

    ``backend`` selects the marginal-gain oracle implementation
    (``jnp`` | ``pallas`` | ``pallas-interpret`` | ``auto``); ``None``
    defers to the process default (``REPRO_ORACLE_BACKEND`` env var, else
    ``auto``).  See ``repro.core.oracle`` / DESIGN.md §5.
    """

    K: int
    d: int
    kernel: KernelConfig = KernelConfig()
    a: float = 1.0
    dtype: jnp.dtype = jnp.float32
    backend: str | None = None

    @property
    def oracle(self):
        """The batched gain oracle every query below routes through."""
        from . import oracle

        return oracle.make(self.kernel, self.a, backend=self.backend,
                           dtype=self.dtype)

    # -- constants -----------------------------------------------------------
    @property
    def singleton_value(self) -> float:
        """m = f({e}) for normalized kernels — known analytically (paper §4)."""
        import math

        return 0.5 * math.log(1.0 + self.a)

    # -- state ---------------------------------------------------------------
    def init(self) -> LogDetState:
        K = self.K
        eye = jnp.eye(K, dtype=self.dtype)
        return LogDetState(
            feats=jnp.zeros((K, self.d), self.dtype),
            L=eye,
            Linv=eye,
            n=jnp.zeros((), jnp.int32),
            fval=jnp.zeros((), self.dtype),
            n_queries=jnp.zeros((), jnp.int32),
        )

    def _mask(self, state: LogDetState) -> Array:
        return (jnp.arange(self.K) < state.n).astype(self.dtype)

    # -- queries --------------------------------------------------------------
    def gains(self, state: LogDetState, X: Array,
              kern: KernelParams | None = None) -> Array:
        """Marginal gains Delta_f(x | S) for a batch X (B, d) -> (B,).

        One fused batch query — (K,B) kernel block, one (K,K)x(K,B) matmul —
        dispatched through the pluggable ``GainOracle`` backend.  ``kern``
        (optional ``KernelParams``) switches the kernel hyperparameters
        from trace constants to traced arrays — the sieve family passes
        ``state.hp.kern`` so per-session kernels share one program.
        """
        return self.oracle.gains(state.feats, state.Linv, state.n, X,
                                 kern=kern)

    def gain1(self, state: LogDetState, x: Array,
              kern: KernelParams | None = None) -> Array:
        """Single-item marginal gain (d,) -> ()."""
        return self.oracle.gain1(state.feats, state.Linv, state.n, x,
                                 kern=kern)

    # -- update ---------------------------------------------------------------
    def append(self, state: LogDetState, x: Array,
               kern: KernelParams | None = None) -> LogDetState:
        """Add x to the summary (caller guarantees state.n < K).

        With ``kern`` the kernel row and the whitening matvec use the
        traced-kernel row form (the exact op sequence the fused pod-step
        kernel replays); without it the static ``KernelConfig`` path is
        bit-frozen for the baselines.
        """
        x = x.astype(self.dtype)
        mask = self._mask(state)
        if kern is None:
            kx = self.kernel.pairwise(state.feats, x[None, :])[:, 0] * mask
            c = state.Linv @ (self.a * kx)  # (K,)
        else:
            kx = pairwise_traced(x[None, :], state.feats, kern)[0] * mask
            # multiply-reduce form of Linv @ (a * kx): unlike the (1, K)
            # matvec, this lowering is bit-stable under vmap — the fused
            # pod-step kernel (unbatched per grid cell) must match the
            # vmapped session axis bit for bit
            c = jnp.sum(state.Linv * (self.a * kx)[None, :], axis=-1)  # (K,)
        dd2 = jnp.maximum((1.0 + self.a) - jnp.sum(c * c), GAIN_EPS)
        dd = jnp.sqrt(dd2)
        gain = 0.5 * jnp.log(dd2)

        n = state.n
        # L row n := [c, dd] ; padded diag was 1 -> overwrite.
        Lrow = c.at[n].set(dd)
        L = state.L.at[n].set(Lrow)
        # Linv row n := [-(c @ Linv)/dd, 1/dd]
        r = -(c @ state.Linv) / dd
        Linv_row = r.at[n].set(1.0 / dd)
        Linv = state.Linv.at[n].set(Linv_row)
        feats = state.feats.at[n].set(x)
        return LogDetState(
            feats=feats,
            L=L,
            Linv=Linv,
            n=n + 1,
            fval=state.fval + gain,
            n_queries=state.n_queries,
        )

    def maybe_append(self, state: LogDetState, x: Array, take: Array,
                     kern: KernelParams | None = None) -> LogDetState:
        """Conditionally append (vmap/select friendly)."""
        appended = self.append(state, x, kern)
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(take, a, b), appended, state
        )

    # -- batch (re)evaluation ---------------------------------------------------
    def refactor(self, feats: Array, n: Array) -> LogDetState:
        """Full O(K^3) factorization of a given summary buffer.

        Used by replacement-based baselines (ISI, Preemption) and by the
        final evaluation of Random.  Padded rows/cols are identity, so they
        contribute 0 to the log-determinant.  Works for any buffer length
        (QuickStream evaluates rings larger than K).
        """
        K = feats.shape[0]
        live = jnp.arange(K) < n
        m2 = live[:, None] & live[None, :]
        Kmat = self.kernel.pairwise(feats, feats)
        M = jnp.where(m2, jnp.eye(K, dtype=self.dtype) + self.a * Kmat,
                      jnp.eye(K, dtype=self.dtype))
        L = jnp.linalg.cholesky(M)
        Linv = jax.scipy.linalg.solve_triangular(
            L, jnp.eye(K, dtype=self.dtype), lower=True
        )
        fval = jnp.sum(jnp.where(live, jnp.log(jnp.diagonal(L)), 0.0))
        return LogDetState(
            feats=jnp.where(live[:, None], feats, 0.0).astype(self.dtype),
            L=L,
            Linv=Linv,
            n=n.astype(jnp.int32),
            fval=fval.astype(self.dtype),
            n_queries=jnp.zeros((), jnp.int32),
        )

    def evaluate(self, feats: Array, n: Array) -> Array:
        """f(S) for an explicit summary buffer — the naive oracle."""
        return self.refactor(feats, n).fval


def naive_logdet(feats: Array, kernel: KernelConfig, a: float) -> Array:
    """Pure-numpy-style oracle: f(S) = 1/2 logdet(I + a K_SS) on live rows only.

    Reference for tests; feats has no padding here.
    """
    Kmat = kernel.pairwise(feats, feats)
    M = jnp.eye(feats.shape[0], dtype=Kmat.dtype) + a * Kmat
    sign, ld = jnp.linalg.slogdet(M)
    return 0.5 * ld
