"""Concurrency utilities: the lockdep runtime sanitizer (DESIGN.md §14).

``make_lock`` / ``make_rlock`` are drop-in :mod:`threading` factories;
under ``REPRO_LOCKDEP=1`` they return instrumented locks that raise
:class:`LockOrderError` on the first acquired-before cycle instead of
deadlocking.  The name passed to the factory is the lock's identity in
the order graph and matches the node spelling of the static graph built
by ``tools/podlint`` (``ClassName.attr``).
"""
from .lockdep import (  # noqa: F401
    LockdepLock,
    LockdepRLock,
    LockOrderError,
    edges,
    enabled,
    graph_snapshot,
    make_lock,
    make_rlock,
    reset,
)

__all__ = [
    "LockdepLock", "LockdepRLock", "LockOrderError", "edges", "enabled",
    "graph_snapshot", "make_lock", "make_rlock", "reset",
]
