"""Runtime lock-order sanitizer ("lockdep", after the kernel facility).

The static half (tools/podlint, PL007/PL008) predicts the repo's
acquired-before graph from source; this module *observes* it while the
code runs.  Every lock built through :func:`make_lock` under
``REPRO_LOCKDEP=1`` records, at each blocking acquire, one edge
``held -> acquiring`` per lock currently held by the thread — into one
process-global graph keyed by lock *name* (class granularity:
``"TaggedBuffer._lock"``), not instance.  Before the underlying
acquire can block, the new edge is checked against the graph: if the
acquiring name already reaches a held name, two call paths take these
locks in opposite orders and :class:`LockOrderError` is raised with
both witness stacks — on the *first* inversion ever executed, whether
or not the adverse interleaving happened this run.  Without the env
flag the factories return plain :mod:`threading` locks; the sanitizer
costs nothing in production.

Conventions (same as the kernel's lockdep):

- Name granularity: nesting two *instances* of the same name is an
  inversion (a self-edge) — there is no instance-order the analyser
  could verify.
- Non-blocking acquires (``acquire(False)``, used by
  ``Condition._is_owned``'s probe) neither record nor check: a trylock
  cannot deadlock.
- ``Condition(make_lock(...))`` works: the wrapper exposes
  ``acquire``/``release``/``_is_owned``, so ``wait()`` releases through
  the wrapper (popping the held stack) and the re-acquire is checked
  like any other.

tests/test_lockdep.py asserts the contract, and — the point of the
whole exercise — that every edge observed here is present in the
static graph (observed ⊆ predicted).
"""
from __future__ import annotations

import os
import threading
import traceback
from typing import Dict, List, Optional, Set, Tuple, Union

__all__ = [
    "LockOrderError", "LockdepLock", "LockdepRLock", "make_lock",
    "make_rlock", "enabled", "edges", "graph_snapshot", "reset",
]


class LockOrderError(RuntimeError):
    """A lock acquisition that closes a cycle in the acquired-before
    graph (or re-acquires a non-reentrant lock on the same thread)."""


# process-global order graph; _STATE_LOCK is a plain lock on purpose —
# the sanitizer must not instrument itself
_STATE_LOCK = threading.Lock()
_EDGES: Dict[Tuple[str, str], dict] = {}   # (src, dst) -> witness
_SUCC: Dict[str, Set[str]] = {}            # adjacency over names
_tls = threading.local()


def enabled() -> bool:
    """True when REPRO_LOCKDEP asks for instrumented locks."""
    return os.environ.get("REPRO_LOCKDEP", "").strip().lower() \
        not in ("", "0", "false", "no")


def make_lock(name: str) -> Union[threading.Lock, "LockdepLock"]:
    """A ``threading.Lock``, instrumented under REPRO_LOCKDEP=1.
    ``name`` is the acquired-before graph node — spell it exactly like
    the static key (``"ClassName._lock"``)."""
    return LockdepLock(name) if enabled() else threading.Lock()


def make_rlock(name: str) -> Union[threading.RLock, "LockdepRLock"]:
    """``threading.RLock`` counterpart of :func:`make_lock`."""
    return LockdepRLock(name) if enabled() else threading.RLock()


def _held() -> List[list]:
    """This thread's held stack: mutable ``[lock, name, count]`` rows."""
    h = getattr(_tls, "held", None)
    if h is None:
        h = _tls.held = []
    return h


def _reaches(src: str, dst: str) -> bool:
    """DFS over _SUCC (caller holds _STATE_LOCK)."""
    seen: Set[str] = set()
    work = [src]
    while work:
        n = work.pop()
        if n == dst:
            return True
        if n in seen:
            continue
        seen.add(n)
        work.extend(_SUCC.get(n, ()))
    return False


def _fmt_witness(w: dict) -> str:
    return (f"  first taken in this order by thread "
            f"{w['thread']!r} at:\n{w['stack']}")


def _check_and_record(name: str, held: List[list]) -> None:
    """The edge check, BEFORE the underlying acquire can block."""
    stack = "".join(traceback.format_stack(limit=16)[:-2])
    me = threading.current_thread().name
    with _STATE_LOCK:
        for _lock, h, _count in held:
            if h == name:
                raise LockOrderError(
                    f"lock-order inversion: acquiring a lock named "
                    f"{name!r} while already holding one — same-name "
                    f"locks have no verifiable order\n"
                    f"  second acquisition at:\n{stack}")
            if _reaches(name, h):
                prior = next(
                    (w for (s, d), w in _EDGES.items()
                     if s == name and _reaches(d, h) or (s, d) == (name, h)),
                    None)
                msg = (f"lock-order inversion: acquiring {name!r} while "
                       f"holding {h!r}, but the graph already orders "
                       f"{name!r} before {h!r}\n"
                       f"  this acquisition (thread {me!r}) at:\n{stack}")
                if prior is not None:
                    msg += f"\n{_fmt_witness(prior)}"
                raise LockOrderError(msg)
        for _lock, h, _count in held:
            if (h, name) not in _EDGES:
                _EDGES[(h, name)] = {"thread": me, "stack": stack}
                _SUCC.setdefault(h, set()).add(name)


class LockdepLock:
    """``threading.Lock`` wrapper feeding the acquired-before graph."""

    _reentrant = False

    def __init__(self, name: str) -> None:
        self.name = name
        self._inner = self._make_inner()

    @staticmethod
    def _make_inner():
        return threading.Lock()

    def acquire(self, blocking: bool = True,
                timeout: Optional[float] = -1) -> bool:
        held = _held()
        mine = next((row for row in held if row[0] is self), None)
        if mine is not None:
            if self._reentrant:
                ok = self._inner.acquire(blocking, timeout)
                if ok:
                    mine[2] += 1
                return ok
            if blocking:
                raise LockOrderError(
                    f"self-deadlock: thread "
                    f"{threading.current_thread().name!r} re-acquiring "
                    f"non-reentrant lock {self.name!r} it already holds")
            return False
        if blocking:
            _check_and_record(self.name, held)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            held.append([self, self.name, 1])
        return ok

    def release(self) -> None:
        self._inner.release()
        held = _held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] is self:
                held[i][2] -= 1
                if held[i][2] == 0:
                    del held[i]
                return

    def locked(self) -> bool:
        return self._inner.locked()

    def _is_owned(self) -> bool:
        # accurate ownership for Condition (beats the stdlib's
        # acquire(False) probe, which misreads other-thread holders)
        return any(row[0] is self for row in _held())

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


class LockdepRLock(LockdepLock):
    """``threading.RLock`` wrapper: re-entry is legal and recorded
    once; the outermost release drops the held entry."""

    _reentrant = True

    @staticmethod
    def _make_inner():
        return threading.RLock()


def edges() -> Set[Tuple[str, str]]:
    """The observed acquired-before edges so far."""
    with _STATE_LOCK:
        return set(_EDGES)


def graph_snapshot() -> dict:
    """JSON-shaped observed graph, same vocabulary as the static
    ``lockgraph.json`` artifact."""
    with _STATE_LOCK:
        names = sorted({n for e in _EDGES for n in e})
        return {"locks": names,
                "edges": [{"src": s, "dst": d, "thread": w["thread"]}
                          for (s, d), w in sorted(_EDGES.items())]}


def reset() -> None:
    """Forget every recorded edge (test isolation only)."""
    with _STATE_LOCK:
        _EDGES.clear()
        _SUCC.clear()
